"""A JAX-free stand-in for ``ContinuousScheduler`` driving the HTTP tests.

The front door is duck-typed over the scheduler (``submit`` /
``run_segment`` / ``has_work`` / ``queue`` / ``slots`` / ``stats``), so the
HTTP conformance suite runs against this stub with no model compile: real
``Request`` handles, a real ``BlockAllocator`` (so block-reclaim assertions
are exact), real ``TenantPolicy`` integration, deterministic token
emission (token *i* of a request is a pure function of its prompt), and a
tunable per-segment delay to make heartbeat/backpressure timing testable.

Not collected by pytest (no ``test_`` prefix) — imported by
``test_serve_http.py``.
"""
from __future__ import annotations

import collections
import time

import numpy as np

from repro.serve.policy import Overloaded, RateLimited
from repro.serve.request import (CANCELLED, EXPIRED, FINISHED, RUNNING,
                                 Request, SubmitRequest)
from repro.serve.scheduler import BlockAllocator


def stub_token(prompt, i: int, vocab: int = 997) -> int:
    """Deterministic token *i* for a prompt — the oracle shared by the
    stub and its tests."""
    return int((int(prompt[0]) * 7 + int(prompt[-1]) * 3 + 13 * i) % vocab)


class StubScheduler:
    """Continuous-scheduler lookalike: admit → emit ``steps_per_segment``
    tokens per live slot per segment → retire, with cancel/expiry sweeps at
    segment boundaries and full-budget block allocation, mirroring the real
    scheduler's observable contract."""

    def __init__(self, n_slots: int = 4, n_blocks: int = 32,
                 block_len: int = 8, max_len: int = 128,
                 steps_per_segment: int = 4, segment_delay_s: float = 0.0,
                 eos_id: int | None = None, policy=None,
                 clock=time.monotonic):
        self.n_slots = n_slots
        self.block_len = block_len
        self.max_len = max_len
        self.steps = steps_per_segment
        self.segment_delay_s = segment_delay_s
        self.eos_id = eos_id
        self.policy = policy
        self.clock = clock
        self.trace = None
        self.spec_k = 0
        self.allocator = BlockAllocator(n_blocks)
        self.queue: collections.deque[Request] = collections.deque()
        self.slots: list[Request | None] = [None] * n_slots
        self._next_rid = 0
        # segment counter value at each cancel-retire, for the
        # "blocks reclaimed within one segment" assertions
        self.last_cancel_segment: int | None = None
        self.stats = {
            "segments": 0, "admitted": 0, "retired": 0,
            "cancelled": 0, "expired": 0,
            "blocks_reclaimed_cancel": 0,
            "tenant_tokens": {},
        }

    # -- submit ------------------------------------------------------------

    def submit(self, sub: SubmitRequest) -> Request:
        p = np.asarray(sub.prompt, np.int32).reshape(-1)
        if p.size < 1:
            raise ValueError("empty prompt")
        if sub.max_new_tokens is None or sub.max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got "
                             f"{sub.max_new_tokens}")
        if p.size + sub.max_new_tokens > self.max_len:
            raise ValueError("exceeds max_len")
        if self._blocks_for(p.size, sub.max_new_tokens) > self.allocator.capacity:
            raise ValueError("request larger than the block pool")
        tenant = sub.tenant if sub.tenant is not None else "default"
        ttft = sub.ttft_deadline_s
        if self.policy is not None:
            spec = self.policy.spec_for(tenant)
            priority = (sub.priority if sub.priority is not None
                        else spec.default_priority)
            cls = self.policy.class_for(priority)
            if ttft is None:
                ttft = cls.ttft_deadline_s
            # brownout shed mirrors the real scheduler: checked before the
            # rate gate so a shed never consumes bucket credit
            if self.policy.should_shed(priority):
                raise Overloaded(tenant, self.policy.shed_retry_after(),
                                 priority, self.policy.brownout_level)
            retry = self.policy.charge_rate(tenant, self.clock())
            if retry is not None:
                raise RateLimited(tenant, retry)
            self.policy.note_submitted(tenant)
        else:
            priority = sub.priority if sub.priority is not None else "standard"
        req = Request(rid=self._next_rid, prompt=p,
                      max_new_tokens=sub.max_new_tokens,
                      on_token=sub.on_token, submit_t=self.clock(),
                      ttft_deadline_s=ttft, deadline_s=sub.deadline_s,
                      tenant=tenant, priority=priority)
        self._next_rid += 1
        self.queue.append(req)
        return req

    # -- internals ---------------------------------------------------------

    def _blocks_for(self, prompt_len: int, max_new: int) -> int:
        return -(-(prompt_len + max_new) // self.block_len)

    def _emit(self, req: Request) -> None:
        tok = stub_token(req.prompt, len(req.tokens))
        if req.first_token_t is None:
            req.first_token_t = self.clock()
            if self.policy is not None:
                self.policy.observe_ttft(req.priority,
                                         req.first_token_t - req.submit_t)
        req._emit(tok)
        t = self.stats["tenant_tokens"]
        t[req.tenant] = t.get(req.tenant, 0) + 1
        if self.policy is not None:
            self.policy.note_tokens(req.tenant)

    def _retire(self, slot: int, state: str, reason: str) -> None:
        req = self.slots[slot]
        req.state = state
        req.finish_reason = reason
        req.finish_t = self.clock()
        if self.policy is not None and state == FINISHED:
            self.policy.observe_latency(req.priority,
                                        req.finish_t - req.submit_t)
        released = len(self.allocator.release(slot))
        self.slots[slot] = None
        self.stats["retired"] += 1
        if state == CANCELLED:
            self.stats["cancelled"] += 1
            self.stats["blocks_reclaimed_cancel"] += released
            self.last_cancel_segment = self.stats["segments"]
        elif state == EXPIRED:
            self.stats["expired"] += 1

    def _sweep(self) -> None:
        now = self.clock()
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            if req.cancel_requested:
                self._retire(slot, CANCELLED, "cancelled")
            elif req.deadline_s is not None and now - req.submit_t > req.deadline_s:
                self._retire(slot, EXPIRED, "expired")
        for req in [r for r in self.queue if r.cancel_requested]:
            self.queue.remove(req)
            req.state = CANCELLED
            req.finish_reason = "cancelled"
            req.finish_t = now
            self.stats["cancelled"] += 1
            self.last_cancel_segment = self.stats["segments"]

    def _admit(self) -> None:
        while self.queue:
            free = [s for s, r in enumerate(self.slots) if r is None]
            if not free:
                return
            req = (self.queue[0] if self.policy is None
                   else self.policy.select(self.queue))
            need = self._blocks_for(req.prompt_len, req.max_new_tokens)
            if not self.allocator.can_alloc(need):
                return  # defer the round, preserving order
            if self.policy is None:
                self.queue.popleft()
            else:
                self.policy.on_admitted(self.queue, req)
                self.queue.remove(req)
            slot = free[0]
            self.allocator.alloc(slot, need)
            req.slot_history.append(slot)
            req.state = RUNNING
            self.slots[slot] = req
            self.stats["admitted"] += 1
            self._emit(req)  # the prefill-sampled first token

    def run_segment(self) -> int:
        """One segment: sweep, admit, then up to ``steps_per_segment``
        emissions per live slot; retire at budget/eos."""
        if self.segment_delay_s:
            time.sleep(self.segment_delay_s)
        self.stats["segments"] += 1
        self._sweep()
        if self.policy is not None and self.policy.slo is not None:
            now = self.clock()
            target = self.policy.slo.cfg.target_class
            waiting = [now - r.submit_t for r in self.queue
                       if r.priority == target and r.first_token_t is None]
            self.policy.update_slo(waiting)
        self._admit()
        emitted = 0
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            for _ in range(self.steps):
                if len(req.tokens) >= req.max_new_tokens:
                    break
                self._emit(req)
                emitted += 1
                if self.eos_id is not None and req.tokens[-1] == self.eos_id:
                    break
            if (self.eos_id is not None and req.tokens
                    and req.tokens[-1] == self.eos_id):
                self._retire(slot, FINISHED, "stop")
            elif len(req.tokens) >= req.max_new_tokens:
                self._retire(slot, FINISHED, "length")
        self._sweep()  # honor cancels that landed during the segment
        return emitted

    def has_work(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.slots)

    def run(self) -> None:
        while self.has_work():
            self.run_segment()


def drain_offline(sched, subs):
    """Offline-path oracle: submit everything up front, run to empty,
    return each request's tokens in submission order."""
    handles = [sched.submit(s) for s in subs]
    sched.run()
    return [list(h.tokens) for h in handles]
