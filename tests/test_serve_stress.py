"""Randomized (seeded, hypothesis-style) stress suite for the serve stack.

Each case draws arrival order, prompt lengths, token budgets, scheduler
geometry, and segment mode from a seeded RNG, runs the workload through the
continuous scheduler under BOTH cache layouts × BOTH admission paths
(per-request and batched/chunked prefill) × speculative decoding
(k ∈ {2, 4}; a weak truncated drafter at k=2 so rejection/rollback churns,
an exact self-drafter at k=4 so full windows land), and oracles every
request against a sequential batch-1 ``ServeEngine.generate`` run.  The
paged cases additionally run ``check_block_invariants`` after every segment
(no block mapped to two live slots, free ∪ mapped = pool, table rows mirror
the allocator); speculative cases additionally check the rollback
invariant after every segment (each live slot's device cursor equals
prompt_len + emitted − 1 — rejected draft tails never advance it).

The draw pools are deliberately small (few distinct prompt/budget lengths)
so the per-length compiled programs stay bounded on the CPU smoke box.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.registry import get_arch
from repro.serve import ContinuousScheduler, ServeConfig, ServeEngine, SpecConfig
from repro.sharding.mesh import MeshPlan

PLAN = MeshPlan()
MAX_LEN, BLOCK_LEN = 64, 8
PROMPT_LENS = (3, 5, 8, 13)
NEW_TOKENS = (1, 2, 5, 9, 16)
SPEC_CONFIGS = {
    None: None,
    "spec_k2": SpecConfig(k=2, draft="truncate:1"),
    "spec_k4": SpecConfig(k=4, draft="self", draft_sparsity=0.0),
}


@pytest.fixture(scope="module")
def arch_params():
    arch = get_arch("tinyllama-1.1b", reduced=True)
    params = arch.init_params(jax.random.PRNGKey(0))
    return arch, params


@pytest.fixture(scope="module")
def engines(arch_params):
    """Module-scoped engines so compiled programs are shared across cases."""
    arch, params = arch_params

    def mk(layout, spec=None):
        sc = ServeConfig(max_len=MAX_LEN, kv_layout=layout,
                         block_len=BLOCK_LEN, spec=spec)
        return ServeEngine(arch, params, PLAN, sc)

    out = {"dense": mk("dense"), "paged": mk("paged"), "oracle": mk("dense")}
    for name, spec in SPEC_CONFIGS.items():
        if spec is not None:
            for layout in ("dense", "paged"):
                out[f"{layout}:{name}"] = mk(layout, spec)
    return out


def _draw_workload(rng, n_requests):
    lens = rng.choice(PROMPT_LENS, n_requests)
    news = rng.choice(NEW_TOKENS, n_requests)
    prompts = [rng.randint(0, 256, (n,)).astype(np.int32) for n in lens]
    return prompts, [int(n) for n in news]


def _oracle(engines, prompts, news):
    """Sequential per-request greedy generation (the PR 1 static path)."""
    eng = engines["oracle"]
    return [
        list(np.asarray(eng.generate(jnp.asarray(p)[None, :], n))[0])
        for p, n in zip(prompts, news)
    ]


def _check_rollback_invariant(sched):
    """Each live slot's device cursor must equal prompt_len + emitted − 1:
    accepted tokens advance it one-for-one, rejected draft tails never do
    (rollback = cursor truncation)."""
    if sched.spec is None:
        return
    pos = np.asarray(sched.pos)
    for slot, req in enumerate(sched.slots):
        if req is None or not sched.active[slot]:
            continue  # empty, or still mid-chunked-prefill
        want = req.prompt_len + len(req.tokens) - 1
        assert pos[slot] == want, (slot, int(pos[slot]), want)


def _run_sched(engines, layout, prompts, news, rng, chunked=False, spec=None):
    n_slots = int(rng.randint(2, 4))
    segment_len = int(rng.randint(2, 8))
    mode = ("scan", "while")[int(rng.randint(2))]
    spec_k = SPEC_CONFIGS[spec].k if spec else 0
    kw = {}
    if layout == "paged":
        # pool between "one big request" and dense-equivalent capacity
        # (speculative windows map spec_k extra overshoot positions)
        dense_eq = n_slots * (MAX_LEN // BLOCK_LEN)
        need_max = max(-(-(len(p) + n + spec_k) // BLOCK_LEN)
                       for p, n in zip(prompts, news))
        kw["n_blocks"] = int(rng.randint(need_max, dense_eq + 1))
    if chunked:  # batched/bucketed admission (PR 4); chunk 8 ⇒ buckets (4, 8)
        kw["prefill_chunk"] = 8
        kw["prefill_buckets"] = 2
    key = layout if spec is None else f"{layout}:{spec}"
    sched = ContinuousScheduler(engines[key], n_slots=n_slots,
                                segment_len=segment_len, segment_mode=mode,
                                **kw)
    # arrival order interleaves with service: submit in random bursts
    handles = [None] * len(prompts)
    order = rng.permutation(len(prompts))
    i = 0
    for _ in range(10_000):
        burst = int(rng.randint(1, 4))
        while burst and i < len(order):
            j = int(order[i])
            handles[j] = sched.submit(prompts[j], news[j])
            i, burst = i + 1, burst - 1
        if sched.has_work():
            sched.run_segment()
            sched.check_block_invariants()
            _check_rollback_invariant(sched)
        if i >= len(order) and not sched.has_work():
            return handles, sched
    raise RuntimeError("stress scheduler did not drain")


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_random_workload_matches_sequential_oracle(engines, seed):
    print(f"stress seed={seed}")  # shown on failure — CI reproducibility
    rng = np.random.RandomState(seed)
    prompts, news = _draw_workload(rng, n_requests=int(rng.randint(6, 12)))
    want = _oracle(engines, prompts, news)
    for layout in ("dense", "paged"):
        for chunked in (False, True):
            handles, sched = _run_sched(
                engines, layout, prompts, news,
                np.random.RandomState(seed + 100), chunked=chunked,
            )
            tag = (layout, "chunked" if chunked else "per-request")
            for h, w, n in zip(handles, want, news):
                assert h.done and len(h.tokens) == n
                assert h.tokens == w, (*tag, h.rid, h.tokens, w)
            st = sched.stats
            assert st["admitted"] == st["retired"] == len(prompts)
            if chunked:
                assert st["chunks_prefilled"] >= len(prompts)
            if layout == "paged":
                assert sched.allocator.n_free == sched.allocator.capacity
                assert st["blocks_in_use_peak"] <= sched.n_blocks


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("spec", ["spec_k2", "spec_k4"])
def test_random_workload_speculative_matches_oracle(engines, seed, spec):
    """The speculative schedulers replay the exact stress matrix: same
    seeded workloads, both layouts, oracled bit-for-bit — with the rollback
    and block invariants checked after every segment inside ``_run_sched``."""
    print(f"stress seed={seed} spec={spec}")  # shown on failure — CI repro
    rng = np.random.RandomState(seed)
    prompts, news = _draw_workload(rng, n_requests=int(rng.randint(6, 12)))
    want = _oracle(engines, prompts, news)
    k = SPEC_CONFIGS[spec].k
    for layout in ("dense", "paged"):
        srng = np.random.RandomState(seed + 100)
        # chunked admission rides along on a coin flip, so speculative
        # segments also stress-interleave with mid-prefill slots (the
        # deterministic paged×chunked×spec cover lives in test_serve_spec)
        handles, sched = _run_sched(
            engines, layout, prompts, news, srng,
            chunked=bool(srng.randint(2)), spec=spec,
        )
        for h, w, n in zip(handles, want, news):
            assert h.done and len(h.tokens) == n
            assert h.tokens == w, (layout, spec, h.rid, h.tokens, w)
        st = sched.stats
        assert st["admitted"] == st["retired"] == len(prompts)
        assert st["spec_steps"] > 0
        assert all(1 <= n_ <= k + 1 for n_ in st["accepted_hist"])
        if layout == "paged":
            assert sched.allocator.n_free == sched.allocator.capacity


@pytest.fixture(scope="module")
def qengines(arch_params):
    """int8-KV engines (ISSUE 10).  The oracle is the quant engine's OWN
    sequential generate: the contract is bit-identity against the
    sequential int8-KV path (every prefill variant attends the dequantized
    cache it just wrote), not closeness to the fp32 cache."""
    arch, params = arch_params
    qplan = MeshPlan(cache_quant_int8=True)

    def mk(layout, spec=None):
        sc = ServeConfig(max_len=MAX_LEN, kv_layout=layout,
                         block_len=BLOCK_LEN, spec=spec)
        return ServeEngine(arch, params, qplan, sc)

    out = {"dense": mk("dense"), "paged": mk("paged"), "oracle": mk("dense")}
    for layout in ("dense", "paged"):
        out[f"{layout}:spec_k2"] = mk(layout, SPEC_CONFIGS["spec_k2"])
    return out


@pytest.mark.parametrize("seed", [0, 1])
def test_random_workload_quantized_cache_matches_quant_oracle(qengines, seed):
    """ISSUE 10: under the int8-quantized KV cache the full admission
    matrix — dense/paged × chunked prefill × plain/speculative decode —
    runs first-class (no fallback) and stays bit-identical to the
    sequential int8-KV oracle, with the allocator and rollback invariants
    checked after every segment inside ``_run_sched``."""
    print(f"stress seed={seed} quant=int8")  # shown on failure — CI repro
    rng = np.random.RandomState(seed)
    prompts, news = _draw_workload(rng, n_requests=int(rng.randint(5, 9)))
    want = _oracle(qengines, prompts, news)
    for layout in ("dense", "paged"):
        for spec in (None, "spec_k2"):
            srng = np.random.RandomState(seed + 100)
            # plain runs pin chunked admission on (the composition the
            # fallback removal unlocked); spec runs coin-flip it so
            # draft-and-verify also interleaves with mid-prefill slots
            chunked = True if spec is None else bool(srng.randint(2))
            handles, sched = _run_sched(
                qengines, layout, prompts, news, srng,
                chunked=chunked, spec=spec,
            )
            tag = (layout, spec or "plain",
                   "chunked" if chunked else "per-request")
            for h, w, n in zip(handles, want, news):
                assert h.done and len(h.tokens) == n
                assert h.tokens == w, (*tag, h.rid, h.tokens, w)
            st = sched.stats
            assert st["admitted"] == st["retired"] == len(prompts)
            if chunked:  # the int8 fallback is gone — chunked really ran
                assert sched.chunked and not st["chunked_skip_reason"]
                assert st["chunks_prefilled"] >= len(prompts)
            if spec is not None:
                assert sched.spec is not None
                assert not st["spec_skip_reason"]
                assert st["spec_steps"] > 0
            if layout == "paged":
                assert sched.allocator.n_free == sched.allocator.capacity
                assert st["blocks_in_use_peak"] <= sched.n_blocks


def test_paged_pool_serves_more_context_than_it_holds(engines):
    """The memory-ceiling claim (ISSUE 3): a pool strictly smaller than the
    dense slot cache serves a workload whose summed live context exceeds
    the dense layout's total capacity — with outputs still matching the
    sequential oracle."""
    rng = np.random.RandomState(7)
    n_slots, n_blocks = 2, 8  # pool = 8 blocks = 64 tokens < 2×64 dense
    prompts = [rng.randint(0, 256, (6,)).astype(np.int32) for _ in range(8)]
    news = [26] * 8  # 8 requests × 32 tokens = 256 > n_slots × max_len = 128
    total_context = sum(len(p) + n for p, n in zip(prompts, news))
    assert total_context > n_slots * MAX_LEN
    want = _oracle(engines, prompts, news)

    sched = ContinuousScheduler(engines["paged"], n_slots=n_slots,
                                segment_len=6, n_blocks=n_blocks)
    handles = [sched.submit(p, n) for p, n in zip(prompts, news)]
    while sched.has_work():
        sched.run_segment()
        sched.check_block_invariants()
    for h, w in zip(handles, want):
        assert h.done and h.tokens == w

    pool_bytes = sum(leaf.nbytes
                     for leaf in jax.tree_util.tree_leaves(sched.cache))
    pool_bytes += sched.block_table.nbytes
    dense_abs = engines["dense"].arch.abstract_cache(n_slots, MAX_LEN, PLAN)
    dense_bytes = sum(
        int(np.prod(leaf.shape)) * leaf.dtype.itemsize
        for leaf in jax.tree_util.tree_leaves(dense_abs)
    )
    assert pool_bytes < dense_bytes, (pool_bytes, dense_bytes)
