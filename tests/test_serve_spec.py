"""Speculative decoding (ISSUE 5 acceptance tests): greedy draft-and-verify
through the continuous scheduler must be bit-identical to the plain
scheduler and the sequential oracle — dense AND paged, k ∈ {1, 2, 4},
including eos-within-draft-window and max_new boundary cases — with
rollback as pure cursor truncation (cache beyond the accepted position is
never read), a single compiled spec-segment program per engine, and the
skip/fallback matrix mirroring chunked prefill.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.registry import get_arch
from repro.serve import (
    ContinuousScheduler, ServeConfig, ServeEngine, SpecConfig, spec_accept,
)
from repro.sharding.mesh import MeshPlan

PLAN = MeshPlan()
MAX_LEN, BLOCK_LEN = 64, 8
LENS = [3, 5, 8, 13, 5, 8]
NEWS = [9, 2, 5, 16, 1, 7]  # includes max_new == 1 (admission-only) and 2


@pytest.fixture(scope="module")
def arch_params():
    arch = get_arch("tinyllama-1.1b", reduced=True)
    params = arch.init_params(jax.random.PRNGKey(0))
    return arch, params


@pytest.fixture(scope="module")
def workload():
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, 256, (n,)).astype(np.int32) for n in LENS]
    return prompts, NEWS


def _engine(arch_params, spec=None, layout="dense", **kw):
    arch, params = arch_params
    sc = ServeConfig(max_len=MAX_LEN, kv_layout=layout, block_len=BLOCK_LEN,
                     spec=spec, **kw)
    return ServeEngine(arch, params, PLAN, sc)


def _run(eng, prompts, news, n_slots=3, segment_len=4, mode="while", **kw):
    if eng.sc.kv_layout == "paged":
        kw.setdefault("n_blocks", 24)
    sched = ContinuousScheduler(eng, n_slots=n_slots, segment_len=segment_len,
                                segment_mode=mode, **kw)
    handles = [sched.submit(p, n) for p, n in zip(prompts, news)]
    sched.run()
    assert all(h.done for h in handles)
    return [h.tokens for h in handles], sched


@pytest.fixture(scope="module")
def baseline(arch_params, workload):
    """Plain (non-speculative) scheduler outputs + the sequential oracle."""
    prompts, news = workload
    plain, _ = _run(_engine(arch_params), prompts, news)
    eng = _engine(arch_params)
    oracle = [
        list(np.asarray(eng.generate(jnp.asarray(p)[None, :], n))[0])
        for p, n in zip(prompts, news)
    ]
    assert plain == oracle  # PR 2 contract — spec tests lean on it below
    return plain


# ----------------------------------------------- bit-identicality matrix


@pytest.mark.parametrize("layout", ["dense", "paged"])
@pytest.mark.parametrize("k", [1, 2, 4])
def test_spec_bit_identical(arch_params, workload, baseline, layout, k):
    """Speculative greedy outputs equal the plain scheduler (and therefore
    the sequential oracle) bit-for-bit, whatever the drafter proposes —
    here a deliberately weak 1-layer drafter, so mismatch/rollback paths
    are exercised constantly."""
    prompts, news = workload
    spec = SpecConfig(k=k, draft="truncate:1")
    got, sched = _run(_engine(arch_params, spec, layout), prompts, news)
    assert got == baseline, (layout, k)
    st = sched.stats
    assert st["spec_steps"] > 0
    assert st["spec_emitted"] == sum(c * n for n, c in
                                     st["accepted_hist"].items())
    assert all(1 <= n <= k + 1 for n in st["accepted_hist"])


def test_spec_exact_drafter_accepts_everything(arch_params, workload, baseline):
    """A sparsity-0 self-drafter is an exact conversion of the served
    weights, so every draft matches: apart from eos/budget-truncated steps,
    each draft-and-verify round emits the full k+1 tokens."""
    prompts, news = workload
    spec = SpecConfig(k=2, draft="self", draft_sparsity=0.0)
    got, sched = _run(_engine(arch_params, spec), prompts, news)
    assert got == baseline
    hist = sched.stats["accepted_hist"]
    # full-window emissions dominate; every sub-window step must be
    # explained by a budget edge (one per request at most) — not rejection
    assert hist.get(3, 0) >= sum(c for n, c in hist.items() if n < 3)


def test_spec_sparse_self_drafter_bit_identical(arch_params, workload, baseline):
    """A lossy (75%-sparse) self-drafter changes only the SPEED profile,
    never the output stream."""
    prompts, news = workload
    spec = SpecConfig(k=4, draft="self", draft_sparsity=0.75)
    got, _ = _run(_engine(arch_params, spec), prompts, news)
    assert got == baseline


def test_spec_scan_segments_match_while(arch_params, workload, baseline):
    prompts, news = workload
    spec = SpecConfig(k=2, draft="truncate:1")
    got, _ = _run(_engine(arch_params, spec), prompts, news, mode="scan")
    assert got == baseline


@pytest.mark.parametrize("layout", ["dense", "paged"])
def test_spec_with_chunked_prefill_admission(arch_params, workload, baseline,
                                             layout):
    """Speculative segments × batched/chunked admission, BOTH layouts — on
    the paged one this is the only deterministic cover of verify windows
    landing at the frozen cursors of mid-prefill (claimed, not yet active)
    slots whose block-table rows are still mostly scratch."""
    prompts, news = workload
    spec = SpecConfig(k=2, draft="truncate:1")
    got, _ = _run(_engine(arch_params, spec, layout), prompts, news,
                  prefill_chunk=8, prefill_buckets=2)
    assert got == baseline


# ------------------------------------------------- eos / budget boundaries


@pytest.mark.parametrize("layout", ["dense", "paged"])
def test_eos_within_draft_window(arch_params, layout):
    """An eos landing mid-window must cut acceptance exactly where the
    sequential scheduler stops: the eos is emitted, nothing after it."""
    rng = np.random.RandomState(3)
    prompt = rng.randint(0, 256, (6,)).astype(np.int32)
    base = np.asarray(
        _engine(arch_params).generate(jnp.asarray(prompt)[None, :], 12)
    )[0]
    eos = int(base[5])  # a token greedy decoding emits mid-stream
    want, _ = _run(_engine(arch_params, layout=layout, eos_token=eos),
                   [prompt, prompt[:4]], [12, 8], n_slots=2)
    spec = SpecConfig(k=4, draft="truncate:1")
    got, sched = _run(
        _engine(arch_params, spec, layout=layout, eos_token=eos),
        [prompt, prompt[:4]], [12, 8], n_slots=2,
    )
    assert got == want
    assert got[0][-1] == eos and eos not in got[0][:-1]
    assert len(got[0]) < 12


def test_max_new_boundary_within_window(arch_params, workload, baseline):
    """Budgets that exhaust mid-window (max_new − 1 not a multiple of the
    window) truncate acceptance on the device exactly like the sequential
    limit check; max_new == 1 never reaches a segment at all."""
    prompts, news = workload
    spec = SpecConfig(k=4, draft="self", draft_sparsity=0.0)
    # full acceptance + budgets 1, 2, 5 ⇒ every boundary case is hit
    got, sched = _run(_engine(arch_params, spec), prompts, news, n_slots=2)
    assert got == baseline
    assert all(len(g) == n for g, n in zip(got, news))


# ----------------------------------------------------- rollback invariant


@pytest.mark.parametrize("layout", ["dense", "paged"])
def test_rollback_cache_beyond_cursor_never_read(arch_params, workload,
                                                 baseline, layout):
    """Cursor-truncation rollback is sound iff nothing ever reads cache
    content past a slot's accepted position.  Poison every such position
    with a large finite value between segments — any read of rejected-tail
    (or stale-tenant / free-block) KV would corrupt the greedy stream."""
    prompts, news = workload
    POISON = 1.0e4
    spec = SpecConfig(k=4, draft="truncate:1")
    eng = _engine(arch_params, spec, layout)
    kw = {"n_blocks": 24} if layout == "paged" else {}
    sched = ContinuousScheduler(eng, n_slots=3, segment_len=4,
                                segment_mode="while", **kw)
    handles = [sched.submit(p, n) for p, n in zip(prompts, news)]

    def poison():
        pos = np.asarray(sched.pos)
        if layout == "dense":
            idx = np.arange(MAX_LEN)  # (S,)
            # (n_slots, S): True where position >= slot cursor
            stale = idx[None, :] >= pos[:, None]
            mask = jnp.asarray(stale[None, :, :, None, None])
            sched.cache = {
                name: jnp.where(mask, jnp.asarray(POISON, leaf.dtype), leaf)
                for name, leaf in sched.cache.items()
            }
        else:
            nb_total = sched.n_slots + sched.n_blocks
            bl = sched.block_len
            # physical-block-position grid of logical positions per slot
            stale = np.ones((nb_total, bl), bool)  # default: poison all
            for slot in range(sched.n_slots):
                for j, phys in enumerate(sched.block_table[slot]):
                    logical = j * bl + np.arange(bl)
                    keep = logical < pos[slot]
                    stale[phys] &= ~keep
            mask = jnp.asarray(stale[None, :, :, None, None])
            sched.cache = {
                name: jnp.where(mask, jnp.asarray(POISON, leaf.dtype), leaf)
                for name, leaf in sched.cache.items()
            }

    for _ in range(10_000):
        if not sched.has_work():
            break
        sched.run_segment()
        poison()
    assert [h.tokens for h in handles] == baseline, layout


# ------------------------------------------------- compiled-once / traces


@pytest.mark.parametrize("mode", ["scan", "while"])
def test_spec_segment_compiled_once(arch_params, workload, mode):
    prompts, news = workload
    spec = SpecConfig(k=2, draft="truncate:1")
    eng = _engine(arch_params, spec)
    _, sched = _run(eng, prompts, news, mode=mode)
    seg_key = ("slot_spec_segment" if mode == "scan"
               else "slot_spec_segment_while")
    assert eng.trace_counts[seg_key] == 1
    assert getattr(eng, "_" + seg_key)._cache_size() == 1
    assert eng.call_counts[seg_key] == sched.stats["segments"]
    # the plain segment programs were never traced on the spec path
    assert eng.trace_counts["slot_segment"] == 0
    assert eng.trace_counts["slot_segment_while"] == 0


# ------------------------------------------------------ fallback / config


def test_spec_skip_reason_families():
    """Families without chunk-resume fall back to plain decode with the
    reason surfaced — exactly the chunked-prefill machinery."""
    for arch_id in ("rwkv6-3b", "zamba2-7b"):
        arch = get_arch(arch_id, reduced=True)
        reason = arch.spec_decode_skip_reason()
        assert reason and reason == arch.chunked_prefill_skip_reason()
    assert get_arch("tinyllama-1.1b", reduced=True).supports_spec_decode


def test_spec_falls_back_on_unsupported_family():
    arch = get_arch("rwkv6-3b", reduced=True)
    params = arch.init_params(jax.random.PRNGKey(0))
    sc = ServeConfig(max_len=MAX_LEN, spec=SpecConfig(k=2, draft="truncate:1"))
    eng = ServeEngine(arch, params, PLAN, sc)
    assert eng.spec is None and "rwkv" in eng.spec_skip_reason
    # the scheduler keeps serving (plain decode) and surfaces the reason
    prompts = [np.arange(1, 5, dtype=np.int32)]
    got, sched = _run(eng, prompts, [4], n_slots=1)
    assert sched.spec is None
    assert sched.stats["spec_skip_reason"] == eng.spec_skip_reason
    assert len(got[0]) == 4


def test_spec_runs_first_class_under_int8_cache(arch_params, workload):
    """The int8-quantized KV cache no longer disables speculation
    (ISSUE 10): verify rows attend the same dequantized values sequential
    decode attends, so draft-and-verify stays bit-identical to the
    sequential int8-KV oracle."""
    arch, params = arch_params
    plan = dataclasses.replace(PLAN, cache_quant_int8=True)
    sc = ServeConfig(max_len=MAX_LEN, spec=SpecConfig(k=2, draft="truncate:1"))
    eng = ServeEngine(arch, params, plan, sc)
    assert eng.spec is not None and not eng.spec_skip_reason

    oracle_eng = ServeEngine(arch, params, plan, ServeConfig(max_len=MAX_LEN))
    prompts, news = [np.arange(1, 9, dtype=np.int32),
                     np.arange(3, 8, dtype=np.int32)], [10, 6]
    oracle = [
        list(np.asarray(oracle_eng.generate(jnp.asarray(p)[None, :], n))[0])
        for p, n in zip(prompts, news)
    ]
    got, sched = _run(eng, prompts, news, n_slots=2)
    assert got == oracle
    assert sched.stats["spec_steps"] > 0
    assert sched.stats["spec_skip_reason"] == ""


def test_spec_rejects_sampling_temperature(arch_params):
    arch, params = arch_params
    with pytest.raises(AssertionError, match="greedy-only"):
        ServeEngine(arch, params, PLAN,
                    ServeConfig(max_len=MAX_LEN, temperature=0.7,
                                spec=SpecConfig(k=2)))


def test_spec_window_must_fit_scratch_block(arch_params):
    arch, params = arch_params
    with pytest.raises(AssertionError, match="scratch block"):
        ServeEngine(arch, params, PLAN,
                    ServeConfig(max_len=MAX_LEN, kv_layout="paged",
                                block_len=4, spec=SpecConfig(k=4)))


def test_submit_requires_draft_window_headroom(arch_params):
    eng = _engine(arch_params, SpecConfig(k=4, draft="truncate:1"))
    sched = ContinuousScheduler(eng, n_slots=1)
    with pytest.raises(ValueError, match="draft window"):
        sched.submit(np.arange(1, 31, dtype=np.int32), MAX_LEN - 32)


# ------------------------------------------------ drafter conversion units


def test_drafter_conversion_helpers(arch_params):
    from repro.core.sonic_layers import (
        sparse_draft_params, truncated_draft_params,
    )

    arch, params = arch_params
    # sparsity-0 conversion keeps every block → exact weights (the
    # full-acceptance oracle the matrix tests rely on)
    exact = sparse_draft_params(params, 0.0)
    for a, b in zip(jax.tree_util.tree_leaves(params["layers"]),
                    jax.tree_util.tree_leaves(exact["layers"])):
        assert a.shape == b.shape and a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # clustered conversion confines each layer matrix to the codebook
    # (+ the pruned-block zeros)
    clustered = sparse_draft_params(params, 0.5, num_clusters=8)
    wq = np.asarray(clustered["layers"]["attn"]["wq"]["kernel"][0])
    assert len(np.unique(wq)) <= 9
    # truncation slices the stacked layers and shares everything else
    trunc = truncated_draft_params(params, 1)
    for leaf in jax.tree_util.tree_leaves(trunc["layers"]):
        assert leaf.shape[0] == 1
    assert trunc["embed"]["embedding"] is params["embed"]["embedding"]


# -------------------------------------------------- spec_accept unit tests


def _accept(window, verify, live, pos, limit, eos=-1):
    out = spec_accept(
        jnp.asarray(window, jnp.int32), jnp.asarray(verify, jnp.int32),
        jnp.asarray(live), jnp.asarray(pos, jnp.int32),
        jnp.asarray(limit, jnp.int32), eos,
    )
    return [np.asarray(o) for o in out]


def test_spec_accept_longest_prefix():
    # drafts d=[7, 9]; verifier says [7, 8, 3]: d1 matches v0, d2 != v1
    emitted, n, last = _accept([[5, 7, 9]], [[7, 8, 3]],
                               [True], [10], [100])
    assert emitted.tolist() == [[7, 8, -1]] and n[0] == 2 and last[0] == 8


def test_spec_accept_full_window_and_bonus():
    emitted, n, last = _accept([[5, 7, 8]], [[7, 8, 3]],
                               [True], [10], [100])
    assert emitted.tolist() == [[7, 8, 3]] and n[0] == 3 and last[0] == 3


def test_spec_accept_eos_cuts_window():
    # v0 is eos: emitted, but nothing after — even though drafts match
    emitted, n, last = _accept([[5, 2, 8]], [[2, 8, 3]],
                               [True], [10], [100], eos=2)
    assert emitted.tolist() == [[2, -1, -1]] and n[0] == 1 and last[0] == 2


def test_spec_accept_budget_cuts_window():
    # pos=10, limit=11: after the first emission pos'=11 >= limit → stop
    emitted, n, last = _accept([[5, 7, 8]], [[7, 8, 3]],
                               [True], [10], [11])
    assert emitted.tolist() == [[7, -1, -1]] and n[0] == 1 and last[0] == 7


def test_spec_accept_masked_slot_emits_nothing():
    emitted, n, _ = _accept([[5, 7, 8]], [[7, 8, 3]],
                            [False], [10], [100])
    assert emitted.tolist() == [[-1, -1, -1]] and n[0] == 0
