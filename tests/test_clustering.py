"""C2 — weight clustering unit + property tests (paper §III.B)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)",
)
from hypothesis import given, settings, strategies as st

from repro.core.clustering import (
    ClusteringConfig,
    cluster_params,
    cluster_weights,
    clustering_error,
    density_based_centroids,
    storage_bits,
)


@settings(max_examples=12, deadline=None)
@given(c=st.sampled_from([4, 8, 16, 64]), seed=st.integers(0, 99))
def test_at_most_c_unique_weights(c, seed):
    """The §III.B property: C centroids ⇒ ≤ C unique weights."""
    w = jax.random.normal(jax.random.PRNGKey(seed), (32, 64))
    dense, packed = cluster_weights(w, ClusteringConfig(num_clusters=c, iters=5))
    assert len(np.unique(np.asarray(dense))) <= c
    assert packed.codebook.shape == (c,)


def test_preserve_zero_keeps_sparsity():
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 64))
    w = w * (jnp.abs(w) > 0.8)  # sparse input
    n_zero = int((np.asarray(w) == 0).sum())
    dense, _ = cluster_weights(w, ClusteringConfig(num_clusters=16, preserve_zero=True))
    assert int((np.asarray(dense) == 0).sum()) >= n_zero


def test_density_centroids_track_mass():
    # bimodal: centroids should concentrate near the two modes
    key = jax.random.PRNGKey(1)
    w = jnp.concatenate(
        [jax.random.normal(key, (5000,)) * 0.1 - 2.0,
         jax.random.normal(jax.random.PRNGKey(2), (5000,)) * 0.1 + 2.0]
    )
    cents = np.asarray(density_based_centroids(w, 8))
    assert (np.abs(np.abs(cents) - 2.0) < 0.5).all()


def test_more_clusters_less_error():
    w = jax.random.normal(jax.random.PRNGKey(3), (64, 64))
    errs = [clustering_error(w, ClusteringConfig(num_clusters=c)) for c in (4, 16, 64)]
    assert errs[0] > errs[1] > errs[2]


def test_index_bits_and_storage():
    cfg = ClusteringConfig(num_clusters=64)
    assert cfg.index_bits == 6  # the paper's 6-bit DAC requirement
    assert storage_bits((100, 100), cfg) == 100 * 100 * 6 + 64 * 32


def test_cluster_params_skips_excluded():
    params = {
        "ffn": {"kernel": jax.random.normal(jax.random.PRNGKey(0), (32, 32))},
        "norm": {"scale": jnp.ones((32,))},
    }
    clustered, packed = cluster_params(params, ClusteringConfig(num_clusters=8))
    assert "ffn/kernel" in packed
    assert all("norm" not in k for k in packed)


def test_packed_roundtrip():
    w = jax.random.normal(jax.random.PRNGKey(5), (16, 16))
    dense, packed = cluster_weights(w, ClusteringConfig(num_clusters=8))
    assert np.allclose(np.asarray(packed.dense()), np.asarray(dense), atol=1e-6)
